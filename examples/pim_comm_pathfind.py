"""Pathfinding case study: what would a direct PIM-PIM fabric buy?

Reproduces the paper's Fig. 10-style strong-scaling experiment on the
repro.comm interconnect model: a fixed BFS problem spread over 1 -> N
ranks, with the end-to-end time broken into kernel / h2d / d2h /
inter-DPU phases. Each configuration runs twice — once with today's
host-bounce path (§II-B) and once with a hypothetical direct PIM-PIM
fabric — moving the exact same bytes, so the inter-DPU columns isolate
the fabric's effect.

    PYTHONPATH=src python examples/pim_comm_pathfind.py [--ranks 1 2 4]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.workloads as wl
from repro.core.config import DPUConfig
from repro.core.host import PIMSystem

DPUS_PER_RANK = 4


def run_one(ranks: int, fabric: str, scale: float, link_gbps: float):
    cfg = DPUConfig(n_dpus=ranks * DPUS_PER_RANK, n_ranks=ranks,
                    n_channels=min(ranks, 2), n_tasklets=16,
                    mram_bytes=1 << 21, fabric=fabric,
                    pim_link_gbps=link_gbps)
    sys_ = PIMSystem(cfg)
    wl.get("BFS").run(sys_, n_threads=16, scale=scale)
    return sys_.timeline


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ranks", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--link-gbps", type=float, default=1.0)
    args = ap.parse_args()

    print("== BFS strong scaling, fixed graph, 4 DPUs/rank "
          f"(scale={args.scale}, direct link {args.link_gbps} GB/s) ==")
    hdr = (f"{'ranks':>5} {'dpus':>4} {'fabric':>6} {'total_us':>9} "
           f"{'kernel%':>8} {'h2d%':>6} {'d2h%':>6} {'inter%':>7} "
           f"{'inter_us':>9} {'speedup':>8}")
    print(hdr)
    base_total = None
    ok = True
    for r in args.ranks:
        inter = {}
        for fabric in ("host", "direct"):
            t = run_one(r, fabric, args.scale, args.link_gbps)
            inter[fabric] = t.inter_dpu
            if base_total is None:
                base_total = t.total
            b = t.breakdown()
            print(f"{r:>5} {r * DPUS_PER_RANK:>4} {fabric:>6} "
                  f"{t.total * 1e6:>9.1f} {100 * b['kernel']:>7.1f}% "
                  f"{100 * b['h2d']:>5.1f}% {100 * b['d2h']:>5.1f}% "
                  f"{100 * b['inter_dpu']:>6.1f}% {t.inter_dpu * 1e6:>9.1f} "
                  f"{base_total / t.total:>8.2f}")
        if inter["direct"] >= inter["host"]:
            ok = False
        print(f"      -> direct fabric cuts inter-DPU time "
              f"{inter['host'] * 1e6:.1f}us -> {inter['direct'] * 1e6:.1f}us "
              f"({inter['host'] / max(inter['direct'], 1e-30):.1f}x) "
              f"at equal data volume")
    if not ok:
        raise SystemExit("FAIL: direct fabric did not beat host-bounce")
    print("\nAll configurations: direct PIM-PIM fabric strictly reduces "
          "inter-DPU time vs the host-bounce path (paper's pathfinding "
          "argument for inter-PIM communication support).")


if __name__ == "__main__":
    main()
