"""PIM offload planner: should a memory-bound LM op run on PIM?

Reproduces the paper's motivating scenario (the Facebook quote on
embedding-dominated inference): for GEMV/embedding-gather shapes from the
assigned LM architectures, compare
  * simulated UPMEM-PIM latency (cycle-level, our engine) against
  * a TPU-v5e roofline estimate (bytes / 819 GB/s HBM),
and emit an offload decision per op.

    PYTHONPATH=src python examples/pim_offload_planner.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro.workloads as wl
from repro.core.config import DPUConfig
from repro.core.host import PIMSystem
from repro.launch.roofline import HBM_BW, PEAK_FLOPS


def tpu_time(bytes_moved, flops):
    return max(bytes_moved / HBM_BW, flops / PEAK_FLOPS)


def main():
    # decode-time GEMV: (d_model x d_model) weight, batch-1 activations —
    # the memory-bound primitive PIM targets
    print(f"{'op':34s} {'TPU(est)':>10s} {'PIM(sim)':>10s} "
          f"{'PIM DPUs':>8s} verdict")
    rows = [
        ("gemv d=2048 (qwen3 proj)", 2048),
        ("gemv d=4096 (llama3 proj)", 4096),
    ]
    for name, d in rows:
        # TPU: weight read dominates
        t_tpu = tpu_time(d * d * 2, 2 * d * d)
        # PIM: R=d rows split over DPUs; C=64-wide panels per GEMV kernel
        n_dpus = 16
        cfg = DPUConfig(n_dpus=n_dpus, n_tasklets=16, mram_bytes=1 << 22)
        sys_ = PIMSystem(cfg)
        _, rep = wl.get("GEMV").run(sys_, 16, scale=d / 2048 / n_dpus)
        panels = d // 64  # GEMV workload uses 64-wide panels
        t_pim = rep.kernel_seconds * panels
        verdict = "PIM" if t_pim < t_tpu else "TPU"
        print(f"{name:34s} {t_tpu*1e6:9.1f}u {t_pim*1e6:9.1f}u "
              f"{n_dpus:8d} {verdict}")

    # embedding gather: tiny compute, pure bandwidth -> per-row DMA on PIM
    for tbl_rows, d in ((1 << 20, 128), (1 << 22, 256)):
        batch = 256
        t_tpu = tpu_time(batch * d * 4, 0)
        # PIM: each lookup = one row DMA (d*4 bytes) on its owning DPU;
        # with B lookups spread over 2560 DPUs, ~1 DMA per DPU
        cfg = DPUConfig()
        dma = cfg.row_miss_overhead + int(np.ceil(d * 4 / cfg.effective_mram_bw))
        t_pim = dma / (cfg.freq_mhz * 1e6)  # parallel across DPUs
        d2h = batch * d * 4 / (cfg.d2h_gbps_per_dpu * 1e9 * 64)
        t_pim_total = t_pim + d2h
        verdict = "PIM" if t_pim_total < t_tpu else "TPU (CPU<->DPU link-bound)"
        print(f"{'embed gather %dx%d b=%d' % (tbl_rows, d, batch):34s} "
              f"{t_tpu*1e6:9.1f}u {t_pim_total*1e6:9.1f}u {'2560':>8s} "
              f"{verdict}")
    print("\nfinding (matches paper §IV-C): PIM kernels win on bandwidth, "
          "but the asymmetric CPU<->DPU link dominates end-to-end — the "
          "paper's case for better host-PIM interconnects.")


if __name__ == "__main__":
    main()
