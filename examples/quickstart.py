"""Quickstart: train a tiny llama-family LM on the synthetic corpus,
checkpoint, restart mid-run, and greedy-decode from the served model.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import store
from repro.configs.base import get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import transformer as T
from repro.optim import get_optimizer, warmup_cosine
from repro.serve.engine import ServeEngine
from repro.train import loop as train_loop


def main():
    cfg = get_smoke_config("llama3-8b").replace(
        dtype="float32", n_layers=2, d_model=128, d_ff=256, vocab_size=512)
    opt = get_optimizer("adamw", warmup_cosine(3e-3, warmup=10, total=200))
    state = train_loop.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(state["params"]))
    print(f"model: {n_params/1e6:.2f}M params")

    step = jax.jit(train_loop.make_train_step(cfg, opt, microbatches=2))
    ds = SyntheticLM(cfg, DataConfig(seq_len=64, global_batch=8,
                                     vocab_size=cfg.vocab_size))
    ckpt = tempfile.mkdtemp(prefix="quickstart_ckpt_")
    for i in range(120):
        batch = {k: jnp.asarray(v) for k, v in next(ds).items()}
        state, m = step(state, batch)
        if i % 20 == 0:
            print(f"step {i:4d} loss {float(m['loss']):.3f} "
                  f"gnorm {float(m['grad_norm']):.2f}")
        if i == 60:
            store.save(ckpt, i, {"state": state, "data": ds.state_dict()})
            print("checkpointed at step 60; simulating restart...")
            restored, _ = store.restore(ckpt, {"state": state,
                                               "data": ds.state_dict()})
            state = restored["state"]
            ds.load_state_dict(restored["data"])
    print(f"final loss {float(m['loss']):.3f} (started ~{np.log(512):.2f})")

    eng = ServeEngine(cfg, state["params"], batch=2, capacity=96)
    rng = np.random.default_rng(0)
    for _ in range(3):
        eng.submit(rng.integers(0, cfg.vocab_size, 8), max_new=8)
    outs = eng.run()
    print("served completions:", {k: v for k, v in outs.items()})


if __name__ == "__main__":
    main()
