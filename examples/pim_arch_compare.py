"""Pathfinding demo: compare PIM architectures, then sweep the design
space from one recorded trace.

1. **MIMD vs all-bank** — run the streaming GEMVS workload unchanged on
   three execution backends (UPMEM-style scalar, SIMT vector DPU,
   HBM-PIM all-bank) just by setting ``DPUConfig(backend=...)``, and
   print a per-architecture comparison table.
2. **Record once, replay the sweep** — simulate BFS once, record its
   command stream at the submit seam, then re-price it under every
   (fabric, channel-count) combination with ``repro.trace.replay`` —
   no DPU cycles are re-simulated, so each sweep point costs
   milliseconds instead of a full engine run.

    PYTHONPATH=src python examples/pim_arch_compare.py [--scale 0.05]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import trace
from repro.core.config import DPUConfig
from repro.core.host import PIMSystem
from repro.workloads import get


def arch_compare(scale: float):
    print("== 1. one workload, three architectures (GEMVS, unchanged) ==")
    print(f"{'arch':<14} {'cycles':>9} {'ipc':>7} {'kernel':>12} "
          f"{'end_to_end':>12}")
    for arch, kw in (("mimd-scalar", {}),
                     ("mimd-simt", {"simt_width": 4}),
                     ("hbmpim", {"backend": "hbmpim"})):
        cfg = DPUConfig(n_dpus=8, n_ranks=2, n_channels=2, **kw)
        system = PIMSystem(cfg)
        _, rep = get("GEMVS").run(system, 8, scale=scale, seed=0)
        print(f"{arch:<14} {rep.cycles:>9d} {rep.ipc:>7.3f} "
              f"{rep.kernel_seconds * 1e3:>10.4f}ms "
              f"{system.timeline.end_to_end * 1e3:>10.4f}ms")


def replay_sweep(scale: float):
    print("\n== 2. record BFS once, sweep the interconnect via replay ==")
    base = DPUConfig(n_dpus=8, n_ranks=4, n_channels=2)
    t0 = time.perf_counter()
    system = PIMSystem(base)
    rec = trace.record(system)
    get("BFS").run(system, 8, scale=scale, seed=0)
    system.sync()
    t_live = time.perf_counter() - t0
    print(f"live run: {rec.records and len(rec.records) - 1} records, "
          f"{t_live:.2f}s wall")
    print(f"{'fabric':<8} {'chans':>5} {'inter_dpu':>12} {'end_to_end':>12}")
    for fabric in ("host", "direct", "hier"):
        for channels in (1, 2, 4):
            res = trace.replay(
                rec.records, cfg=base.replace(fabric=fabric,
                                              n_channels=channels))
            print(f"{fabric:<8} {channels:>5d} "
                  f"{res.timeline.inter_dpu * 1e3:>10.4f}ms "
                  f"{res.end_to_end * 1e3:>10.4f}ms")
    # the unchanged config reproduces the live timeline bit-exactly
    res = trace.replay(rec.records)
    assert res.timeline.events == system.timeline.events
    assert res.timeline.elapsed == system.timeline.elapsed
    print("unchanged-config replay: bit-exact vs live timeline")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05)
    args = ap.parse_args()
    arch_compare(args.scale)
    replay_sweep(args.scale)


if __name__ == "__main__":
    main()
