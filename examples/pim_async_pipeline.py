"""Async command-queue runtime demo: hiding host transfers under kernels.

Two parts:

1. **Raw queue/event API** — submit H2D / LAUNCH / D2H commands on
   explicit streams with event dependencies, then ``sync()`` and print
   the resolved schedule as a small gantt, showing a transfer on the
   channel links running concurrently with a kernel holding the rank
   compute slots.
2. **Double-buffered pipeline** — ``Workload.run_pipelined`` on an
   in-order system (serialized, the PR 2 baseline) vs an async system:
   batch k+1's staging and batch k-1's readback hide under batch k's
   kernel, and the exposed transfer time sinks below kernel time.

    PYTHONPATH=src python examples/pim_async_pipeline.py [--scale 0.02]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.workloads as wl
from repro.core.config import DPUConfig
from repro.core.host import PIMSystem


def queue_api_demo():
    print("== 1. raw queues + events (2 ranks x 2 channels) ==")
    cfg = DPUConfig(n_dpus=8, n_ranks=2, n_channels=2, mram_bytes=1 << 21)
    sys_ = PIMSystem(cfg, mode="async")
    MB = 1 << 20

    # stream "xfer": stage the next batch while "compute" runs this one
    with sys_.stream("compute"):
        sys_.h2d(MB, label="stage batch0")
        staged0 = sys_.record_event("batch0 staged")
    with sys_.stream("xfer"):
        sys_.h2d(MB, label="stage batch1")     # overlaps batch0's kernel
    with sys_.stream("compute"):
        sys_.wait_event(staged0)
        # a LAUNCH normally comes from system.launch(); modeled_launch
        # charges a known-duration kernel to keep the demo engine-free
        sys_.modeled_launch("kernel batch0", 0.02)
        kernel0 = sys_.record_event("batch0 kernel done")
    with sys_.stream("xfer"):
        sys_.wait_event(kernel0)
        sys_.d2h(MB, label="drain batch0")

    sched = sys_.sync()
    t = sys_.timeline
    print(f"{'command':>14} {'queue':>8} {'start_ms':>9} {'finish_ms':>10}")
    for it in sorted(sched.items, key=lambda s: (s.start, s.cmd.seq)):
        if it.cmd.seconds == 0:
            continue
        print(f"{it.cmd.label:>14} {it.cmd.queue:>8} "
              f"{it.start * 1e3:>9.2f} {it.finish * 1e3:>10.2f}")
    print(f"serialized sum {t.total * 1e3:.2f} ms vs overlapped makespan "
          f"{t.end_to_end * 1e3:.2f} ms (saved {t.overlap_saved * 1e3:.2f})\n")
    if t.end_to_end >= t.total:
        raise SystemExit("FAIL: async schedule did not overlap anything")


def pipeline_demo(scale: float, n_batches: int):
    print(f"== 2. double-buffered pipeline, VA x {n_batches} batches "
          f"(scale={scale}) ==")
    rows = []
    for ranks in (1, 2):
        cfg = DPUConfig(n_dpus=4 * ranks, n_ranks=ranks,
                        n_channels=min(ranks, 2), n_tasklets=16,
                        mram_bytes=1 << 21)
        ser = PIMSystem(cfg)
        wl.get("VA").run_pipelined(ser, 16, n_batches=n_batches, scale=scale)
        pipe = PIMSystem(cfg, mode="async")
        _, _, sched = wl.get("VA").run_pipelined(pipe, 16,
                                                 n_batches=n_batches,
                                                 scale=scale)
        xfer = pipe.timeline.h2d + pipe.timeline.d2h
        exposed = sched.exposed("kernel")
        rows.append((ranks, ser.timeline.end_to_end, pipe.timeline.end_to_end,
                     pipe.timeline.kernel, xfer, exposed))
    print(f"{'ranks':>5} {'serial_us':>10} {'pipe_us':>9} {'speedup':>8} "
          f"{'kernel_us':>10} {'xfer_us':>8} {'exposed_us':>11}")
    for r, s, p, k, x, e in rows:
        print(f"{r:>5} {s * 1e6:>10.1f} {p * 1e6:>9.1f} {s / p:>8.2f} "
              f"{k * 1e6:>10.1f} {x * 1e6:>8.1f} {e * 1e6:>11.1f}")
    bad = [r for r, s, p, *_ in rows if r >= 2 and p >= s]
    if bad:
        raise SystemExit(f"FAIL: no pipeline speedup at ranks={bad}")
    print("\nPipelined end-to-end beats the serialized baseline; the "
          "exposed (un-hidden) transfer time is far below the raw "
          "transfer total once double-buffered.")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--batches", type=int, default=4)
    args = ap.parse_args()
    queue_api_demo()
    pipeline_demo(args.scale, args.batches)


if __name__ == "__main__":
    main()
