"""Batched serving demo: continuous batching over a slot pool.

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-130m

``--cluster`` submits through the multi-tenant cluster runtime instead
of attaching a private accelerator: the serving replica leases ranks
from a shared :class:`repro.cluster.PimCluster` (fault-aware placement)
and its decode ticks are charged to the shared system's timeline next
to everyone else's work.

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-130m --cluster
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import transformer as T
from repro.serve.engine import ServeEngine


def _cluster_pool(n_ranks: int):
    """Lease decode ranks from a shared fault-aware cluster."""
    from repro.cluster import PimCluster
    from repro.core.config import DPUConfig
    from repro.core.host import PIMSystem
    system = PIMSystem(DPUConfig(n_dpus=32, n_ranks=8, n_channels=4,
                                 mram_bytes=1 << 20), mode="async")
    cluster = PimCluster(system, policy="fault_aware", spare_ranks=2)
    lease = cluster.lease("serve_lm", n_ranks=n_ranks)
    return cluster, lease


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--cluster", action="store_true",
                    help="lease decode ranks from the shared PIM cluster")
    ap.add_argument("--lease-ranks", type=int, default=2)
    args = ap.parse_args()

    cluster = lease = None
    pool = None
    if args.cluster:
        cluster, lease = _cluster_pool(args.lease_ranks)

    cfg = get_smoke_config(args.arch).replace(dtype="float32")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch=4, capacity=128,
                      pim_pool=lease.pool if lease else pool)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(4, 12))
        eng.submit(rng.integers(0, cfg.vocab_size, plen),
                   max_new=args.max_new)
    outs = eng.run()
    dt = time.time() - t0
    total = sum(len(v) for v in outs.values())
    print(f"arch={cfg.name} served {len(outs)} requests "
          f"({total} tokens) in {dt:.1f}s on a 4-slot pool")
    if cluster is not None:
        tl = cluster.system.timeline
        print(f"cluster lease: ranks={list(lease.ranks)} "
              f"policy={cluster.policy} "
              f"pim_ticks={eng.stats['pim_ticks']} "
              f"host_ticks={eng.stats['host_ticks']} "
              f"modeled_decode={tl.kernel * 1e3:.2f}ms")
        cluster.release(lease)
    for rid, toks in sorted(outs.items()):
        print(f"  req{rid}: {toks}")


if __name__ == "__main__":
    main()
