"""Batched serving demo: continuous batching over a slot pool.

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-130m
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import transformer as T
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).replace(dtype="float32")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch=4, capacity=128)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(4, 12))
        eng.submit(rng.integers(0, cfg.vocab_size, plen),
                   max_new=args.max_new)
    outs = eng.run()
    dt = time.time() - t0
    total = sum(len(v) for v in outs.values())
    print(f"arch={cfg.name} served {len(outs)} requests "
          f"({total} tokens) in {dt:.1f}s on a 4-slot pool")
    for rid, toks in sorted(outs.items()):
        print(f"  req{rid}: {toks}")


if __name__ == "__main__":
    main()
