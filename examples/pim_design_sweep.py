"""Datacenter-scale PIM pathfinding (the paper's §V at fleet scale).

The paper sweeps one design point at a time on one machine; here the
(design x workload) grid is over-decomposed into work units and scheduled
onto a simulated worker fleet with the straggler-aware
:class:`WorkRebalancer` — the same structure a 1000-chip sweep uses, with
each TPU chip simulating a slice of the grid (DESIGN.md §3).

    PYTHONPATH=src python examples/pim_design_sweep.py
"""
import argparse
import itertools
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro.workloads as wl
from repro.core.config import DPUConfig
from repro.core.host import PIMSystem
from repro.runtime.coordinator import StepMonitor, WorkRebalancer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--workers", type=int, default=4)
    args = ap.parse_args()

    designs = {
        "base": {},
        "ilp(D+R)": dict(forwarding=True, unified_rf=True),
        "ilp(D+R+S)": dict(forwarding=True, unified_rf=True, superscalar=2),
        "ilp+700MHz": dict(forwarding=True, unified_rf=True, superscalar=2,
                           freq_mhz=700),
        "bw_x2": dict(mram_bw_scale=2.0),
        "ilp+bw_x2": dict(forwarding=True, unified_rf=True, superscalar=2,
                          mram_bw_scale=2.0),
    }
    workloads = ["VA", "RED", "BS", "TS", "GEMV", "HST-S"]
    units = list(itertools.product(designs, workloads))

    # --- schedule the grid onto the fleet (LPT with observed rates) ---
    est_cost = np.array([2.0 if w in ("TS", "GEMV") else 1.0
                         for _, w in units])
    rates = np.ones(args.workers)
    rates[-1] = 0.5  # one deliberately slow worker (straggler)
    rb = WorkRebalancer(args.workers)
    assignment = rb.assign(est_cost, rates)
    print(f"{len(units)} work units over {args.workers} workers; "
          f"makespan(model) = {rb.makespan(assignment, est_cost, rates):.1f} "
          f"(naive contiguous = "
          f"{rb.makespan([list(range(i, len(units), args.workers)) for i in range(args.workers)], est_cost, rates):.1f})")

    # --- execute (serially here; each unit is one fleet work item) ---
    mon = StepMonitor()
    results = {}
    for w, unit_list in enumerate(assignment):
        for u in unit_list:
            dname, wname = units[u]
            cfg = DPUConfig(n_dpus=1, n_tasklets=16, mram_bytes=1 << 21,
                            **designs[dname])
            t0 = time.time()
            _, rep = wl.get(wname).run(PIMSystem(cfg), 16, scale=args.scale)
            mon.observe(time.time() - t0)
            results[(dname, wname)] = rep.kernel_seconds

    print(f"\n{'design':14s} " + " ".join(f"{w:>7s}" for w in workloads)
          + "   geomean speedup")
    base = np.array([results[("base", w)] for w in workloads])
    for d in designs:
        t = np.array([results[(d, w)] for w in workloads])
        sp = base / t
        print(f"{d:14s} " + " ".join(f"{s:7.2f}" for s in sp)
              + f"   {float(np.exp(np.mean(np.log(sp)))):.2f}x")
    best = max(designs, key=lambda d: np.exp(np.mean(np.log(
        base / np.array([results[(d, w)] for w in workloads])))))
    print(f"\npathfinding verdict: '{best}' wins at iso-workload "
          f"(paper §V-B: ILP features unlock compute-bound PIM workloads)")


if __name__ == "__main__":
    main()
