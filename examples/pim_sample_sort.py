"""Distributed sample sort across the three inter-DPU fabrics.

Runs the SSORT workload (local sort kernel -> splitter gather/broadcast
-> alltoall bucket exchange -> merge kernel) on the same keys under

* ``host``   — today's UPMEM path: every exchanged byte bounces
  DPU -> CPU -> DPU over the asymmetric host links (paper §II-B);
* ``direct`` — the paper's pathfinding hypothesis: a PIM-PIM
  interconnect with per-DPU links;
* ``hier``   — rank-locality pathfinding: a fast intra-rank stage plus
  a cross-rank stage among rank leaders.

The sorted output is validated against ``np.sort`` inside the workload
for every backend (the collectives move identical bytes; only the
charged time differs), and the exchange-time gap quantifies how much an
alltoall-bound workload gains from a real inter-DPU interconnect.

    PYTHONPATH=src python examples/pim_sample_sort.py [--scale 0.05]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.workloads as wl  # noqa: E402
from repro.core.config import DPUConfig  # noqa: E402
from repro.core.host import PIMSystem  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--dpus", type=int, default=4)
    ap.add_argument("--ranks", type=int, default=2)
    ap.add_argument("--tasklets", type=int, default=8)
    args = ap.parse_args()

    rows = {}
    for fabric in ("host", "direct", "hier"):
        cfg = DPUConfig(n_dpus=args.dpus, n_ranks=args.ranks,
                        n_channels=min(args.ranks, 2),
                        n_tasklets=args.tasklets, mram_bytes=1 << 21,
                        fabric=fabric)
        system = PIMSystem(cfg)
        _, rep = wl.get("SSORT").run(system, n_threads=args.tasklets,
                                     scale=args.scale)
        rows[fabric] = (system.timeline, system.timeline.by_label(
            "inter_dpu"))

    print(f"== SSORT, {args.dpus} DPUs x {args.ranks} ranks "
          f"(scale={args.scale}; oracle-checked on every backend) ==")
    print(f"{'fabric':>7} {'end_to_end_us':>13} {'exchange_us':>12} "
          f"{'alltoall_us':>12} {'gather_us':>10} {'bcast_us':>9}")
    for fabric, (t, by) in rows.items():
        print(f"{fabric:>7} {t.end_to_end * 1e6:>13.1f} "
              f"{t.inter_dpu * 1e6:>12.2f} "
              f"{by.get('alltoall', 0) * 1e6:>12.2f} "
              f"{by.get('gather', 0) * 1e6:>10.2f} "
              f"{by.get('broadcast', 0) * 1e6:>9.2f}")

    host_x = rows["host"][0].inter_dpu
    bad = [f for f in ("direct", "hier") if rows[f][0].inter_dpu >= host_x]
    if bad:
        raise SystemExit(f"FAIL: {bad} did not beat the host bounce on "
                         "the alltoall exchange")
    print("\nBoth pathfinding fabrics beat the host bounce on the "
          "alltoall-bound exchange phase; the hierarchical design "
          "additionally keeps the intra-rank share of the transpose on "
          "fast local links "
          f"(host {host_x * 1e6:.1f} us -> direct "
          f"{rows['direct'][0].inter_dpu * 1e6:.2f} us, hier "
          f"{rows['hier'][0].inter_dpu * 1e6:.2f} us).")


if __name__ == "__main__":
    main()
