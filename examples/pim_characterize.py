"""Reproduce the paper's single-DPU characterization for one workload:
Fig. 5 (utilization), Fig. 6 (latency breakdown), Fig. 7/8 (TLP in space
and time) and Fig. 9 (instruction mix) from ONE simulation per thread
count — the exact methodology of paper §IV.

    PYTHONPATH=src python examples/pim_characterize.py --workload BS
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro.workloads as wl
from repro.core.config import DPUConfig
from repro.core.host import PIMSystem


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="BS")
    ap.add_argument("--scale", type=float, default=0.1)
    args = ap.parse_args()

    W = wl.get(args.workload)
    print(f"== {W.name} (paper Table II workload, scaled x{args.scale}) ==")
    for nt in (1, 2, 4, 8, 16):
        cfg = DPUConfig(n_dpus=1, n_tasklets=16, mram_bytes=1 << 21)
        sys_ = PIMSystem(cfg)
        _, rep = W.run(sys_, n_threads=nt, scale=args.scale)
        b = rep.breakdown
        print(f"threads={nt:2d} cycles={rep.cycles:9,d} "
              f"IPC={rep.ipc:.3f} mramBW={rep.mram_read_bw_util:.3f} | "
              f"active={b['active']:.2f} mem={b['idle_memory']:.2f} "
              f"rev={b['idle_revolver']:.2f} rf={b['idle_rf']:.2f}")
    print("\ninstruction mix (16 threads):")
    for k, v in rep.instr_mix.items():
        print(f"  {k:10s} {v:6.1%}")
    h = rep.hist / max(rep.hist.sum(), 1)
    print(f"\nTLP: avg issuable={rep.avg_issuable:.2f}  "
          f"P(issuable=0)={h[0]:.2%}")
    ts = [t for t in rep.ts[0] if t > 0][:16]
    print("TLP time series (per-window avg):",
          " ".join(f"{t:.1f}" for t in ts))


if __name__ == "__main__":
    main()
